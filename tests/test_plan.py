"""PrecisionPlan API: construction-time validation, JSON + checkpoint
round-trips, the plan→Env constructor, per-entry wire accounting vs the
CompressionPolicy formulas, the chunk sweep, and the plan-only step
factory signatures (the legacy precision kwargs are gone)."""
import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import load_checkpoint, load_plan, save_checkpoint
from repro.configs.registry import get_config, reduced
from repro.core.awp import AWPConfig, AWPController
from repro.dist.spec import (
    SINGLE, MeshCfg, build_spec_tree, dist_elems_per_group, tree_to_storage,
)
from repro.models.init import init_params
from repro.optim.sgd import SGDConfig, init_momentum
from repro.plan import (
    PrecisionPlan, Schedule, modeled_gather_time, pick_chunks, sweep_chunks,
)
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.step import make_train_step
from repro.transport import CompressionPolicy


# ---------------------------------------------------------------------------
# construction + validation
# ---------------------------------------------------------------------------


def test_defaults_are_paper_baseline():
    p = PrecisionPlan()
    assert p.round_tos == (4,)
    assert not p.needs_rng
    assert p.schedule.source == "static"
    assert p.compute_dtype == jnp.float32


@pytest.mark.parametrize(
    "kw",
    [
        dict(weights=()),                                   # no entries
        dict(weights=({"round_to": 5},)),                   # bad round_to
        dict(weights=({"round_to": 2, "mode": "floor"},)),  # bad mode
        dict(chunks=0),                                     # chunks < 1
        dict(chunks=1.5),                                   # non-int chunks
        dict(dtype="fp16"),                                 # unknown dtype
        dict(accum_steps=0),
        dict(schedule={"source": "magic"}),                 # unknown schedule
        dict(schedule={"source": "awp", "awp_interval": 0}),
        dict(env_overrides={"int8_kv": True}),              # plan-owned knob
        dict(activations={"round_to": 2, "mode": "stochastic"}),  # no PRNG path
        dict(seq_boundary={"round_to": 2, "grad_mode": "stochastic",
                           "grad_round_to": 2}),
    ],
)
def test_invalid_plans_raise_at_construction(kw):
    with pytest.raises((ValueError, TypeError)):
        PrecisionPlan(**kw)


def test_broadcast_and_with_round_tos():
    p = PrecisionPlan.build(1, round_to=2).broadcast(5)
    assert p.round_tos == (2,) * 5
    assert p.with_round_tos((1, 2, 3, 4, 4)).round_tos == (1, 2, 3, 4, 4)
    with pytest.raises(ValueError):
        p.broadcast(3)  # 5 entries cannot become 3
    # a 1-entry plan broadcasts through with_round_tos too
    assert PrecisionPlan().with_round_tos((2, 2)).round_tos == (2, 2)


def test_gradients_entry_folds_into_weight_policies():
    p = PrecisionPlan.build(
        2, round_to=2, grad_round_to=1, grad_mode="stochastic", chunks=4
    )
    for pol in p.weight_policies():
        assert pol.round_to == 2
        assert pol.grad_round_to == 1
        assert pol.grad_mode == "stochastic"
        assert pol.chunks == 4
    assert p.needs_rng
    # without a gradients entry the weight entries keep their own fields
    q = PrecisionPlan(weights=(CompressionPolicy(round_to=2, grad_round_to=3),))
    assert q.weight_policies()[0].grad_round_to == 3
    assert not q.needs_rng


def test_needs_rng_stable_under_awp_widening():
    """The step signature must never flip when AWP swaps widths: a plan
    with a stochastic mode configured needs a key at EVERY width (an
    uncompressed stochastic policy simply ignores it)."""
    p = PrecisionPlan.build(
        2, round_to=2, mode="stochastic", schedule="awp"
    )
    assert p.needs_rng
    assert p.with_round_tos((4, 4)).needs_rng  # widened to fp32: still keyed
    g = PrecisionPlan.build(2, round_to=4, grad_round_to=2,
                            grad_mode="stochastic")
    assert g.needs_rng and g.with_round_tos((1, 1)).needs_rng
    # and a fully deterministic plan never asks for one
    assert not PrecisionPlan.build(2, round_to=2).with_round_tos((1, 1)).needs_rng


def test_plan_wire_split_mixed_widths():
    """plan_wire_split only subtracts the *compressing* groups from the
    measured plane wire: an rt=4 group's gather is raw f32, not planes."""
    from repro.roofline.hlo_cost import Cost, plan_wire_split

    plan = PrecisionPlan(
        weights=(CompressionPolicy(round_to=2, grad_round_to=2),
                 CompressionPolicy(round_to=4)),
    )
    elems, n = [4096, 4096], 4
    pols = plan.weight_policies()
    plane_bytes = (pols[0].all_gather_wire_bytes(1024, n)
                   + pols[0].reduce_scatter_wire_bytes(1024, n))
    cost = Cost(wire={"all-gather": plane_bytes},
                plane_wire={"all-gather": plane_bytes})
    split = plan_wire_split(cost, plan, elems, n)
    # all measured planes are attributed; nothing of the rt=4 group's
    # analytic f32 bytes is subtracted, so the residue is exactly zero
    assert split["plane_residue"] == 0
    # the analytic table itself still counts the rt=4 group
    assert split["weights"] > pols[0].all_gather_wire_bytes(1024, n)


def test_seq_boundary_defaults_to_activations():
    act = CompressionPolicy(round_to=2, grad_round_to=2, mode="nearest")
    p = PrecisionPlan(activations=act)
    assert p.seq_policy() == act
    sb = CompressionPolicy(round_to=1, grad_round_to=1, mode="nearest")
    assert PrecisionPlan(activations=act, seq_boundary=sb).seq_policy() == sb


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------


def test_json_roundtrip_exact():
    p = PrecisionPlan.build(
        3, round_to=2, mode="nearest", grad_round_to=2,
        grad_mode="stochastic", act_round_to=2, seq_parallel=True,
        chunks=2, dtype="bf16", int8_kv=True, accum_steps=2,
        schedule="awp", awp_threshold=-1e-3, awp_interval=7,
        env_overrides={"causal_skip": False},
    )
    assert PrecisionPlan.from_json(p.to_json()) == p
    # and through a file
    d = json.loads(p.to_json())
    assert d["version"] == 1
    assert len(d["weights"]) == 3


def test_json_rejects_unknown_fields_and_versions():
    with pytest.raises(ValueError):
        PrecisionPlan.from_json_dict({"version": 9, "weights": [{}]})
    with pytest.raises(ValueError):
        PrecisionPlan.from_json_dict({"weights": [{}], "turbo": True})
    with pytest.raises(ValueError):
        PrecisionPlan.from_json_dict({"version": 1})  # no weights


def test_plan_file_roundtrip(tmp_path):
    p = PrecisionPlan.build(2, round_to=2, seq_parallel=True)
    path = str(tmp_path / "plan.json")
    p.to_file(path)
    assert PrecisionPlan.from_file(path) == p


# ---------------------------------------------------------------------------
# plan -> Env (the deduped env constructor)
# ---------------------------------------------------------------------------


def test_make_env_from_plan():
    act = CompressionPolicy(round_to=2, grad_round_to=2, mode="nearest")
    p = PrecisionPlan(
        weights=(CompressionPolicy(round_to=2),),
        activations=act,
        seq_parallel=True,
        dtype="bf16",
        int8_kv=True,
        env_overrides={"causal_skip": False, "mlstm_chunk": 8},
    )
    mesh_cfg = MeshCfg(tp=2, dp=2)
    env = p.make_env(mesh_cfg)
    assert env.model_axis == "model" and env.fsdp_axes == ("data",)
    assert env.tp == 2 and env.dtype == jnp.bfloat16
    assert env.act_policy == act and env.seq_policy is None
    assert env._seq_pol == act  # seq boundary rides the act policy
    assert env.seq_parallel and env.int8_kv
    assert not env.causal_skip and env.mlstm_chunk == 8
    # trivial mesh: no axes, and seq_parallel can be overridden off
    env1 = p.make_env(SINGLE, seq_parallel=False)
    assert env1.model_axis is None and env1.fsdp_axes is None
    assert not env1.seq_parallel


# ---------------------------------------------------------------------------
# per-entry wire accounting vs the policy formulas
# ---------------------------------------------------------------------------


def test_wire_table_matches_policy_formulas():
    p = PrecisionPlan.build(2, round_to=2, grad_round_to=1)
    elems = [4096, 1024]
    n = 4
    t = p.wire_table(elems, n)
    pols = p.weight_policies()
    assert t["weights"] == sum(
        pol.all_gather_wire_bytes(e // n, n) for pol, e in zip(pols, elems)
    )
    assert t["gradients"] == sum(
        pol.reduce_scatter_wire_bytes(e // n, n) for pol, e in zip(pols, elems)
    )
    assert t["host_device"] == 0
    assert t["total"] == t["weights"] + t["gradients"]
    # serving: no gradient entry
    assert p.wire_table(elems, n, training=False)["gradients"] == 0
    # single gather shard -> the paper's host->device staging model
    t1 = p.wire_table(elems, 1)
    assert t1["weights"] == 0 and t1["gradients"] == 0
    assert t1["host_device"] == sum(
        pol.host_device_bytes(e) for pol, e in zip(pols, elems)
    )
    # activation entries appear when the TP geometry is known
    pa = dataclasses.replace(
        p, activations=CompressionPolicy(round_to=2, grad_round_to=2)
    )
    ta = pa.wire_table(elems, n, tp=2, act_elems=512, act_collectives=3)
    assert ta["activations"] == 3 * pa.activations.all_reduce_wire_bytes(512, 2)
    ps = dataclasses.replace(pa, seq_parallel=True)
    ts = ps.wire_table(elems, n, tp=2, act_elems=512, act_collectives=3)
    assert ts["seq_boundary"] == 3 * ps.seq_policy().seq_pair_wire_bytes(512, 2)
    assert ts["activations"] == 0


def test_trainer_wire_log_per_entry():
    from repro.train.loop import Trainer

    p = PrecisionPlan.build(2, round_to=2, grad_round_to=2)
    calls = []

    def builder(rts):
        def fake_step(storage, opt, batch, lr):
            calls.append(rts)
            return storage, opt, {
                "loss": 1.0, "group_norms_sq": np.ones(2)
            }
        return fake_step

    tr = Trainer(builder, 2, plan=p, dist_elems_per_group=[1024, 256],
                 gather_axis_size=4)
    assert tr.policy == "plan"  # static schedule pins the plan's formats
    tr.run_step({}, {}, {}, 0.1)
    rec = tr.records[-1]
    assert rec.round_tos == (2, 2)
    assert rec.wire_by_entry is not None
    assert rec.wire_bytes == rec.wire_by_entry["total"]
    assert rec.wire_by_entry == p.wire_table([1024, 256], 4)
    s = tr.summary()
    assert s["wire_by_entry"]["weights"] == rec.wire_by_entry["weights"]
    # awp schedule wires the plan's controller hyper-parameters in
    pa = PrecisionPlan.build(
        2, schedule="awp", awp_threshold=-5e-4, awp_interval=3,
    )
    tra = Trainer(builder, 2, plan=pa)
    assert tra.policy == "awp"
    assert tra.controller.config.threshold == -5e-4
    assert tra.controller.config.interval == 3


# ---------------------------------------------------------------------------
# chunk sweep
# ---------------------------------------------------------------------------


def test_chunk_sweep_picks_divisible_optimum():
    table = sweep_chunks(1 << 20, 8, 2)
    assert set(table) == {1, 2, 4, 8, 16}
    best = pick_chunks(1 << 20, 8, 2)
    assert table[best] == min(table.values())
    # non-dividing candidates are excluded (silent-fallback trap)
    assert set(sweep_chunks(6, 2, 2)) == {1, 2}
    # degenerate gathers keep the unchunked pipeline
    assert pick_chunks(0, 8) == 1
    assert pick_chunks(1 << 20, 1) == 1
    assert pick_chunks(7, 8) == 1  # prime shard: nothing divides
    # the model is monotone in the obvious places: a chunked pipeline
    # never models slower than 3x the unchunked one at these sizes
    assert modeled_gather_time(1 << 20, 8, CompressionPolicy(round_to=2), best) \
        <= 3 * modeled_gather_time(1 << 20, 8, CompressionPolicy(round_to=2), 1)


# ---------------------------------------------------------------------------
# checkpoint round-trip: plan + AWP schedule state
# ---------------------------------------------------------------------------


def test_checkpoint_persists_plan_and_awp(tmp_path):
    storage = {"a": jnp.arange(8, dtype=jnp.float32)}
    opt = {"m": jnp.zeros((8,))}
    plan = PrecisionPlan.build(
        3, round_to=2, grad_round_to=2, grad_mode="stochastic",
        schedule="awp", awp_threshold=-1e-3, awp_interval=2,
    )
    awp = AWPController(3, plan.awp_config())
    norms = np.array([1.0, 2.0, 3.0])
    awp.update(norms**2)
    awp.update((norms * 0.8) ** 2)
    awp.update((norms * 0.6) ** 2)  # widen fires
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, storage, opt, awp, step=5, plan=plan)

    got = load_plan(path)
    assert got == plan
    awp2 = AWPController(3, got.awp_config())
    s2, o2, step = load_checkpoint(path, storage, opt, awp2)
    assert step == 5
    np.testing.assert_array_equal(awp2.state.bits, awp.state.bits)
    assert awp2.history == awp.history
    # the restored plan + AWP bits reproduce the exact wire formats
    assert got.with_round_tos(awp2.state.round_to()).round_tos \
        == awp.state.round_to()
    # checkpoints without a plan stay loadable
    save_checkpoint(str(tmp_path / "old"), storage, opt, None, step=1)
    assert load_plan(str(tmp_path / "old")) is None


# ---------------------------------------------------------------------------
# plan= is the only entry point: legacy signatures are hard errors
# ---------------------------------------------------------------------------


def _tiny_lm():
    cfg = reduced(get_config("qwen3-1.7b"))
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec = build_spec_tree(params, metas, SINGLE)
    storage = tree_to_storage(params, spec, SINGLE)
    return cfg, spec, storage


def test_legacy_train_signature_removed():
    cfg, spec, storage = _tiny_lm()
    nrt = cfg.num_groups + 1
    bsh = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
           "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    opt = SGDConfig(lr=0.05, momentum=0.9, weight_decay=0.0)
    act2 = CompressionPolicy(round_to=2, grad_round_to=2, mode="nearest")

    # the pre-plan kwarg sprawl is gone: round_tos / grad_round_to /
    # act_policy are unknown kwargs, not a deprecation shim
    with pytest.raises(TypeError):
        make_train_step(
            cfg, SINGLE, None, spec, opt, bsh,
            round_tos=(2,) * nrt, grad_round_to=2, act_policy=act2,
        )
    # the old 3-positional (round_tos, opt_cfg, batch_shapes) form too
    with pytest.raises(TypeError):
        make_train_step(cfg, SINGLE, None, spec, (2,) * nrt, opt, bsh)
    # and omitting plan= entirely names the required argument
    with pytest.raises(TypeError, match="plan="):
        make_train_step(cfg, SINGLE, None, spec, opt, bsh)
    # PrecisionPlan.from_legacy went with the shims
    assert not hasattr(PrecisionPlan, "from_legacy")


def test_legacy_serve_signature_removed():
    cfg, spec, storage = _tiny_lm()
    nrt = cfg.num_groups + 1
    B, S = 2, 8
    bsh = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    with pytest.raises(TypeError):
        make_prefill_step(
            cfg, SINGLE, None, spec, (4,) * nrt, bsh, cache_capacity=S + 1
        )
    dsh = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
           "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    with pytest.raises(TypeError):
        make_decode_step(
            cfg, SINGLE, None, spec, (4,) * nrt, dsh,
            env_kw={"int8_kv": False},
        )
    # the plan path still serves: prefill + one decode step stay finite
    plan = PrecisionPlan.build(nrt)
    pre = make_prefill_step(
        cfg, SINGLE, None, spec, bsh, plan=plan, cache_capacity=S + 1
    )
    logits, caches = pre(storage, {"tokens": jnp.ones((B, S), jnp.int32)})
    dec = make_decode_step(cfg, SINGLE, None, spec, dsh, plan=plan)
    dl, _ = dec(storage, caches,
                {"tokens": jnp.ones((B, 1), jnp.int32),
                 "pos": jnp.asarray(S, jnp.int32)})
    assert np.isfinite(np.asarray(dl)).all()


def test_serve_rejects_stochastic_forward():
    cfg, spec, _ = _tiny_lm()
    nrt = cfg.num_groups + 1
    bsh = {"tokens": jax.ShapeDtypeStruct((2, 8), jnp.int32)}
    with pytest.raises(ValueError, match="stochastic"):
        make_prefill_step(
            cfg, SINGLE, None, spec, bsh,
            plan=PrecisionPlan.build(nrt, round_to=2, mode="stochastic"),
            cache_capacity=9,
        )


def test_legacy_cnn_signature_removed():
    from repro.models.cnn import ALEXNET, init_cnn, reduced_cnn
    from repro.train.cnn_step import (
        build_cnn_spec_tree, cnn_to_storage, make_cnn_train_step,
    )

    ccfg = reduced_cnn(ALEXNET, num_classes=10, in_hw=32)
    mesh = MeshCfg(tp=1, dp=1, compress_min_size=256)
    p, m, gi = init_cnn(ccfg, jax.random.PRNGKey(0))
    spec = build_cnn_spec_tree(p, m, mesh)
    st = cnn_to_storage(p, spec, mesh)
    _, ng = gi
    opt = SGDConfig(lr=0.05, momentum=0.9, weight_decay=5e-4)
    # legacy (round_tos, opt_cfg, batch_shapes) positional form is gone
    with pytest.raises(TypeError):
        make_cnn_train_step(ccfg, mesh, None, spec, gi, (2,) * ng, opt, {})
    step = make_cnn_train_step(
        ccfg, mesh, None, spec, gi, opt, {},
        plan=PrecisionPlan.build(ng, round_to=2),
    )
    imgs = jnp.zeros((4, 32, 32, 3))
    labels = jnp.zeros((4,), jnp.int32)
    st, mom, met = step(st, init_momentum(st),
                        {"images": imgs, "labels": labels}, 0.05,
                        jax.random.PRNGKey(0))
    assert np.isfinite(float(met["loss"]))


# ---------------------------------------------------------------------------
# stochastic rounding statistics (single device)
# ---------------------------------------------------------------------------


def test_stochastic_rounding_unbiased_vs_nearest():
    from repro.transport import quantize

    w = jnp.asarray(np.random.default_rng(0).normal(size=(256,)), jnp.float32)
    pol_s = CompressionPolicy(round_to=2, mode="stochastic")
    pol_n = CompressionPolicy(round_to=2, mode="nearest")
    qs = np.stack([
        np.asarray(quantize(w, pol_s, jax.random.PRNGKey(i)))
        for i in range(64)
    ])
    # different keys -> different realizations; mean approaches w
    assert np.any(qs[0] != qs[1])
    ulp = np.abs(np.asarray(quantize(w, pol_n)) - np.asarray(w)).max() * 2 + 1e-12
    assert np.abs(qs.mean(0) - np.asarray(w)).max() < ulp
    # same key -> bit-identical (reproducible training)
    np.testing.assert_array_equal(
        qs[3], np.asarray(quantize(w, pol_s, jax.random.PRNGKey(3)))
    )
