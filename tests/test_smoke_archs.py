"""Per-architecture smoke tests: reduced config (2-ish layers, d_model<=256,
<=4 experts), one forward + one SGD train step on CPU; asserts shapes and
finiteness. Also exercises prefill+decode for decoder archs."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, reduced
from repro.models.env import Env
from repro.models.init import init_params
from repro.models import model as M

ARCH_IDS = sorted(ARCHS)


def _identity_mat(g, key, storage):
    return storage


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.embed_is_input_stub:
        batch["features"] = jnp.asarray(
            rng.normal(0, 1, (B, S, cfg.vision_dim)).astype(np.float32)
        )
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
        )
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    )
    if cfg.num_image_tokens:
        batch["image_features"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_image_tokens, cfg.vision_dim)).astype(
                np.float32
            )
        )
    return batch


def _loss_fn(params, batch, cfg, env):
    loss_sum, metrics = M.forward_loss(
        params, batch, cfg, env,
        mat_group=_identity_mat,
        mat_top=lambda name: params[name],
    )
    return loss_sum / jnp.maximum(metrics["token_count"], 1.0) + 1e-2 * metrics["aux"]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    env = Env(attn_chunk=16)
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    batch = _batch(cfg)

    loss, grads = jax.jit(
        jax.value_and_grad(_loss_fn), static_argnums=(2, 3)
    )(params, batch, cfg, env)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # plausible initial loss: near log(vocab)
    assert 0.1 < float(loss) < 3 * np.log(cfg.vocab_size) + 5

    gnorms = [float(jnp.sum(g * g)) for g in jax.tree_util.tree_leaves(grads)]
    assert all(np.isfinite(gn) for gn in gnorms), f"{arch}: non-finite grads"
    assert sum(gnorms) > 0, f"{arch}: all-zero grads"

    # one SGD step reduces nothing catastrophic (finite + changed)
    params2 = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = jax.jit(_loss_fn, static_argnums=(2, 3))(params2, batch, cfg, env)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize(
    "arch", [a for a in ARCH_IDS if ARCHS[a].causal]
)
def test_smoke_prefill_decode(arch):
    cfg = reduced(get_config(arch))
    env = Env(attn_chunk=8)
    params, _ = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S)
    mat_top = lambda name: params[name]

    logits, caches = jax.jit(
        functools.partial(
            M.forward_prefill, cfg=cfg, env=env,
            mat_group=_identity_mat, mat_top=mat_top, cache_capacity=S + 4,
        )
    )(params, batch)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert np.all(np.isfinite(np.asarray(logits)))

    step = {
        "tokens": jnp.zeros((B, 1), jnp.int32),
        "pos": jnp.asarray(S, jnp.int32),
    }
    if cfg.num_image_tokens:
        step["image_features"] = batch["image_features"]
    logits2, caches2 = jax.jit(
        functools.partial(
            M.forward_decode, cfg=cfg, env=env,
            mat_group=_identity_mat, mat_top=mat_top,
        )
    )(params, step, caches)
    assert logits2.shape[:2] == (B, 1)
    assert np.all(np.isfinite(np.asarray(logits2)))
