import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
)

"""``python -m repro.audit`` — static data-motion sweep over the registry.

Traces every (arch × plan × mesh × seq-layout) combo with abstract
inputs, attributes each communication eqn to a plan traffic class, and
fails unless the jaxpr-derived wire bytes exactly equal the analytic
model with zero unattributed eqns. The two lines above MUST run before
any other import (jax locks the device count on first init).

Usage:
  PYTHONPATH=src python -m repro.audit                       # full sweep
  PYTHONPATH=src python -m repro.audit --archs qwen3-1.7b \
      --kinds train,prefill --meshes 1x2 --plans rt2 --json report.json
"""
import argparse
import json
import sys
import time

from repro.audit.audit import audit_step
from repro.audit.cases import PLAN_NAMES, build_case, make_plan, parse_mesh
from repro.configs.registry import ARCHS, get_config, reduced


def _fmt_classes(report) -> str:
    parts = []
    for name, c in sorted(report.classes.items()):
        parts.append(f"{name}={round(c.jaxpr_bytes)}")
    return " ".join(parts) or "-"


def run_sweep(archs, kinds, meshes, plans, *, seq_parallel="auto",
              seq_len=32, global_batch=4, verbose=True):
    """Returns (results, n_failed). Each result is a JSON-ready dict."""
    results = []
    n_failed = 0
    for arch in archs:
        cfg = reduced(get_config(arch))  # build_case audits reduced cfgs
        num_entries = cfg.num_groups + 1
        for mesh_spec in meshes:
            mesh_cfg = parse_mesh(mesh_spec)
            layouts = [False]
            if seq_parallel == "on":
                layouts = [True]
            elif seq_parallel == "auto" and mesh_cfg.tp > 1:
                layouts = [False, True]
            for plan_name in plans:
                for sp in layouts:
                    for kind in kinds:
                        if sp and kind == "decode":
                            continue  # decode has no sequence dim to shard
                        plan = make_plan(
                            plan_name, num_entries, seq_parallel=sp
                        )
                        combo = dict(
                            arch=arch, kind=kind, mesh=mesh_spec,
                            plan=plan_name, seq_parallel=sp,
                        )
                        t0 = time.time()
                        case = build_case(
                            arch, kind, mesh_cfg, plan,
                            seq_len=seq_len, global_batch=global_batch,
                        )
                        if case is None:
                            combo["skipped"] = "not applicable"
                            results.append(combo)
                            continue
                        try:
                            report = audit_step(
                                case.step, case.args, case.plan,
                                mesh_cfg=mesh_cfg,
                                spec_tree=case.spec_tree,
                                kind=kind, mesh=case.mesh,
                            )
                        except Exception as exc:  # trace-time failure
                            combo["error"] = f"{type(exc).__name__}: {exc}"
                            results.append(combo)
                            n_failed += 1
                            if verbose:
                                print(f"ERROR {combo['arch']} {kind} "
                                      f"{mesh_spec} {plan_name}: "
                                      f"{combo['error']}")
                            continue
                        combo["report"] = report.to_json_dict()
                        combo["trace_s"] = round(time.time() - t0, 2)
                        results.append(combo)
                        if not report.ok:
                            n_failed += 1
                        if verbose:
                            status = "ok" if report.ok else "FAIL"
                            sp_tag = " sp" if sp else ""
                            print(
                                f"{status:4s} {arch:20s} {kind:8s} "
                                f"{mesh_spec}{sp_tag:3s} {plan_name:11s} "
                                f"eqns={report.n_comm_eqns:3d} "
                                f"{_fmt_classes(report)}"
                            )
                            for v in report.violations:
                                print(f"       ! {v}")
    return results, n_failed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.audit",
        description="static jaxpr data-motion audit over the registry",
    )
    ap.add_argument("--archs", default="all",
                    help="comma-separated arch names, or 'all'")
    ap.add_argument("--kinds", default="train",
                    help="train,prefill,decode,place")
    ap.add_argument("--meshes", default="1x2,2x1",
                    help="comma-separated dpxtp specs")
    ap.add_argument("--plans", default=",".join(PLAN_NAMES))
    ap.add_argument("--seq-parallel", choices=("auto", "on", "off"),
                    default="auto",
                    help="auto: audit both layouts wherever tp > 1")
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--json", default=None,
                    help="write the per-config attribution report here")
    args = ap.parse_args(argv)

    archs = list(ARCHS) if args.archs == "all" else args.archs.split(",")
    results, n_failed = run_sweep(
        archs,
        args.kinds.split(","),
        args.meshes.split(","),
        args.plans.split(","),
        seq_parallel=args.seq_parallel,
        seq_len=args.seq_len,
        global_batch=args.global_batch,
    )
    audited = [r for r in results if "report" in r]
    print(
        f"\naudited {len(audited)} combos "
        f"({len(results) - len(audited)} skipped/errored), "
        f"{n_failed} failed"
    )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                {"results": results, "failed": n_failed}, f, indent=1
            )
        print(f"report -> {args.json}")
    return 1 if n_failed else 0


if __name__ == "__main__":
    sys.exit(main())
