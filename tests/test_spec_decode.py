"""Speculative decoding + per-request sampling on the serve engine.

The contracts pinned here (see docs/serving.md §sampling/§speculative):

  * sampled streams follow the key-fold contract, so the engine is
    BIT-exact vs the static reference and invariant under arrival-order
    permutations, fp32 and int8 KV alike;
  * speculative decoding is token-identical to non-speculative sampling
    at the same per-request seeds — the draft moves only the acceptance
    rate; a draft equal to the target pins ``acceptance_rate == 1.0``;
  * the engine's measured ``host_device`` bytes under speculation equal
    :func:`repro.roofline.analysis.serve_spec_decode_bytes` — the
    fourth measured==analytic wire instance (contiguous AND paged);
  * the unified :class:`repro.serve.api.Request` is the one submit
    surface; the legacy kwargs/tuple/``image_features=`` shims still
    work one release behind ``DeprecationWarning``;
  * MoE over the dispatch capacity floor warns a typed
    :class:`CapacityWarning`, and ``check_spec_arch`` refuses the archs
    whose decode couples positions.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config, reduced
from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
from repro.models.init import init_params
from repro.plan import PrecisionPlan, SamplingParams
from repro.roofline.analysis import serve_spec_decode_bytes
from repro.serve.api import legacy_request
from repro.serve.engine import (
    CapacityWarning,
    Request,
    ServeEngine,
    generate_static,
)
from repro.serve.spec import DraftBundle, build_draft, check_spec_arch
from repro.transport import CompressionPolicy

SLOTS = 2
CAPACITY = 32
SPEC_K = 3


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3-1.7b"))
    mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=4096)
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    plan = PrecisionPlan(
        weights=(CompressionPolicy(round_to=2),) * (cfg.num_groups + 1),
        host_device=CompressionPolicy(round_to=2),
    )
    return cfg, mesh_cfg, spec_tree, storage, plan


def _sampled_requests(cfg, spec=((16, 8), (12, 8), (16, 8), (8, 8))):
    rng = np.random.default_rng(0)
    reqs = []
    for i, (S, gen) in enumerate(spec):
        # request 2 stays greedy: mixed batches must keep both paths
        samp = (SamplingParams() if i == 2 else SamplingParams(
            temperature=0.8, top_p=0.95, top_k=40, seed=100 + i))
        reqs.append(Request(
            rid=i,
            prompt_ids=tuple(int(t) for t in rng.integers(0, cfg.vocab_size, S)),
            max_new=gen,
            sampling=samp,
        ))
    return reqs


@pytest.fixture(scope="module")
def sampled_static(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    return generate_static(
        cfg, mesh_cfg, None, spec_tree, storage, _sampled_requests(cfg),
        plan=plan,
    )


# ---------------------------------------------------------------------------
# per-request sampling: engine == static, permutation-invariant
# ---------------------------------------------------------------------------


def test_sampled_engine_matches_static(setup, sampled_static):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    reqs = _sampled_requests(cfg)
    results = ServeEngine(
        cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
        max_slots=SLOTS, cache_capacity=CAPACITY,
    ).run(reqs)
    for r in reqs:
        assert results[r.rid].tokens == sampled_static[r.rid], r.rid


def test_sampled_streams_invariant_under_arrival_permutation(
    setup, sampled_static
):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    reqs = _sampled_requests(cfg)
    for order in (list(reversed(reqs)), [reqs[1], reqs[3], reqs[0], reqs[2]]):
        results = ServeEngine(
            cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
            max_slots=SLOTS, cache_capacity=CAPACITY,
        ).run(order)
        for r in reqs:
            assert results[r.rid].tokens == sampled_static[r.rid], r.rid


def test_sampled_int8_kv_matches_static(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    p = dataclasses.replace(plan, int8_kv=True)
    reqs = _sampled_requests(cfg)
    static = generate_static(
        cfg, mesh_cfg, None, spec_tree, storage, reqs, plan=p
    )
    results = ServeEngine(
        cfg, mesh_cfg, None, spec_tree, storage, plan=p,
        max_slots=SLOTS, cache_capacity=CAPACITY,
    ).run(reqs)
    for r in reqs:
        assert results[r.rid].tokens == static[r.rid], r.rid


# ---------------------------------------------------------------------------
# speculative decoding: token-identical, counters, wire pin
# ---------------------------------------------------------------------------


def _wire_pin(eng, reqs, plan, cfg, *, paged=False):
    w = eng.wire_summary()
    analytic = serve_spec_decode_bytes(
        plan, cfg.vocab_size, n_slots=eng.max_slots,
        prompt_lens=[len(r.prompt_ids) for r in reqs],
        spec_rounds=w["spec_rounds"], spec_k=eng.spec_k,
        page_table_entries=w["page_table_entries"] if paged else 0,
    )
    assert w["host_device"] == analytic["total"], (w, analytic)
    return w


def test_self_draft_is_token_identical_with_full_acceptance(
    setup, sampled_static
):
    # a draft that IS the target: every proposal matches the target's
    # sample, so acceptance pins at exactly 1.0 and every round emits
    # up to k+1 ids per slot
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    reqs = _sampled_requests(cfg)
    eng = ServeEngine(
        cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
        max_slots=SLOTS, cache_capacity=CAPACITY,
        draft=DraftBundle(cfg, spec_tree, storage), spec_k=SPEC_K,
    )
    results = eng.run(reqs)
    for r in reqs:
        assert results[r.rid].tokens == sampled_static[r.rid], r.rid
    w = _wire_pin(eng, reqs, plan, cfg)
    assert w["acceptance_rate"] == 1.0
    assert w["tokens_per_target_step"] > 1.0
    assert w["spec_k"] == SPEC_K


def test_tiny_draft_is_token_identical(setup, sampled_static):
    # a *different* draft changes acceptance, never content
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    reqs = _sampled_requests(cfg)
    eng = ServeEngine(
        cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
        max_slots=SLOTS, cache_capacity=CAPACITY,
        draft=build_draft(cfg, mesh_cfg, "tiny"), spec_k=SPEC_K,
    )
    results = eng.run(reqs)
    for r in reqs:
        assert results[r.rid].tokens == sampled_static[r.rid], r.rid
    w = _wire_pin(eng, reqs, plan, cfg)
    assert 0.0 <= w["acceptance_rate"] <= 1.0
    assert w["tokens_per_target_step"] >= 1.0


def test_paged_spec_decode_wire_pin(setup, sampled_static):
    # paged + int8 KV + speculation: streams hold and the analytic model
    # prices the widened page-table staging (4·entries·rounds)
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    p = dataclasses.replace(plan, int8_kv=True)
    reqs = _sampled_requests(cfg)
    static = generate_static(
        cfg, mesh_cfg, None, spec_tree, storage, reqs, plan=p
    )
    eng = ServeEngine(
        cfg, mesh_cfg, None, spec_tree, storage, plan=p,
        max_slots=SLOTS, cache_capacity=CAPACITY, paged=True, page_size=8,
        draft=DraftBundle(cfg, spec_tree, storage), spec_k=SPEC_K,
    )
    results = eng.run(reqs)
    for r in reqs:
        assert results[r.rid].tokens == static[r.rid], r.rid
    w = _wire_pin(eng, reqs, p, cfg, paged=True)
    assert w["acceptance_rate"] == 1.0
    audit = eng.pages.audit()
    assert audit["live"] == 0 and audit["allocs"] == audit["releases"]


def test_spec_arch_gate():
    check_spec_arch(reduced(get_config("qwen3-1.7b")))  # passes
    with pytest.raises(ValueError, match="capacity dispatch|MoE|pattern"):
        check_spec_arch(reduced(get_config("mixtral-8x7b")))
    with pytest.raises(ValueError):
        check_spec_arch(reduced(get_config("recurrentgemma-9b")))
    with pytest.raises(ValueError):
        check_spec_arch(reduced(get_config("qwen3-1.7b")), window=16)
    with pytest.raises(ValueError):
        check_spec_arch(reduced(get_config("hubert-xlarge")))


def test_draft_vocab_must_match(setup):
    cfg, mesh_cfg, *_ = setup
    with pytest.raises(ValueError, match="vocab"):
        build_draft(cfg, mesh_cfg, "qwen2.5-14b")


# ---------------------------------------------------------------------------
# unified Request API: deprecation shims + typed capacity warning
# ---------------------------------------------------------------------------


def test_request_legacy_kwargs_warn():
    with pytest.warns(DeprecationWarning, match="prompt_ids"):
        r = Request(rid=0, prompt=(1, 2, 3), max_new_tokens=4)
    assert r.prompt_ids == (1, 2, 3) and r.max_new == 4
    with pytest.warns(DeprecationWarning):
        r2 = legacy_request(1, [5, 6], 2, eos_id=9)
    assert r2 == Request(rid=1, prompt_ids=(5, 6), max_new=2, eos_id=9)


def test_request_read_properties_do_not_warn(recwarn):
    r = Request(rid=0, prompt_ids=(1, 2), max_new=3)
    assert r.prompt == (1, 2)
    assert r.max_new_tokens == 3
    assert not [w for w in recwarn if w.category is DeprecationWarning]
    with pytest.raises(dataclasses.FrozenInstanceError):
        r.max_new = 5


def test_generate_static_image_features_kwarg_warns(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    req = Request(rid=0, prompt_ids=(1, 2, 3, 4), max_new=1)
    with pytest.warns(DeprecationWarning, match="image_features"):
        out = generate_static(
            cfg, mesh_cfg, None, spec_tree, storage, [req], plan=plan,
            image_features={},
        )
    assert len(out[0]) == 1


def test_moe_capacity_warning_is_typed():
    cfg = reduced(get_config("mixtral-8x7b"))
    assert cfg.num_experts
    slots = 8 // cfg.top_k + 1  # first slot count over the floor
    mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=4096)
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    plan = PrecisionPlan(
        weights=(CompressionPolicy(round_to=2),) * (cfg.num_groups + 1),
        host_device=CompressionPolicy(round_to=2),
    )
    with pytest.warns(CapacityWarning, match="capacity floor"):
        ServeEngine(
            cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
            max_slots=slots, cache_capacity=16,
        )
