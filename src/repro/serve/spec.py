"""Speculative decoding for the serve engine (`repro.serve.spec`).

A small **draft** model (same registry family, shrunk config, same
vocab) proposes ``k`` tokens per slot with the request's own
:class:`~repro.plan.SamplingParams` keys; the target then scores the
carried last-emitted token plus all k proposals in ONE ``(B, k+1)``
verify decode step (:func:`repro.serve.step.make_verify_step`) and
samples its own token at every block position with the same per-request
keys. Acceptance is the deterministic rule: emit the target's token at
position j, and keep consuming the block while the draft's next
proposal equals it — so the emitted stream is *token-identical to
non-speculative sampling by construction* (each emitted token is the
target's sample given a prefix the draft reproduced exactly), and the
draft model only moves the acceptance rate, never the stream.

Data motion: draft feeds/proposals and the verify block all ride the
lossless ``host_device`` byte planes at ``token_wire_width`` bytes per
id — per round ``(k+1) + k`` draft crossings plus ``2·(k+1)`` verify
crossings per slot. The analytic mirror is
:func:`repro.roofline.analysis.serve_spec_decode_bytes`, pinned EQUAL
to the engine's measured ``step_log``.

Cache discipline: the verify step advances every slot's ``pos`` by
``k+1`` and writes the whole block; :func:`rollback_caches` then
re-stamps ``pos`` back by the per-slot count of rejected positions.
Stale entries past the rolled-back ``pos`` are mask-invisible and are
overwritten bit-identically by the next round's block (per-row
determinism), so no data is ever copied back.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
from repro.models import model as M
from repro.models.init import init_params
from repro.plan import PrecisionPlan
from repro.serve.sampling import sample_tokens
from repro.serve.step import (
    global_cache_shapes,
    make_decode_step,
    make_prefill_step,
)
from repro.transport.hostdev import (
    pack_tokens,
    pack_tokens_host,
    stage,
    unpack_tokens,
    unpack_tokens_host,
)

__all__ = [
    "DraftBundle",
    "DraftRunner",
    "build_draft",
    "check_spec_arch",
    "make_draft_config",
    "rollback_caches",
]


def check_spec_arch(cfg: ModelConfig, *, window=None) -> None:
    """Speculative decoding serves pure-attention causal token models —
    the family where a k-token block write + pos rollback is exact
    (recurrent state and MoE capacity dispatch couple positions, and
    ring caches physically overwrite on advance)."""
    if not cfg.causal:
        raise ValueError(f"{cfg.name} is encoder-only: nothing to serve")
    if cfg.num_image_tokens or cfg.embed_is_input_stub:
        raise ValueError(
            f"{cfg.name}: speculative decoding stages token payloads only"
        )
    if cfg.num_experts or any(kind != "attn" for kind in cfg.pattern):
        raise ValueError(
            f"{cfg.name}: speculative decoding needs a pure-attention "
            "pattern (MoE capacity dispatch and recurrent state make "
            "block verify + rollback inexact)"
        )
    if cfg.sliding_window or window is not None:
        raise ValueError(
            f"{cfg.name}: speculative decoding keeps linear per-slot "
            "caches — ring (sliding-window) layouts overwrite on "
            "advance and cannot roll back"
        )


def make_draft_config(cfg: ModelConfig, name: str = "tiny") -> ModelConfig:
    """The draft model's config. ``"tiny"`` auto-shrinks the target
    (2 layers, narrow width) while PRESERVING ``vocab_size`` — the
    registry's ``reduced()`` shrinks the vocab too, which would break
    token exchange. Any other name resolves through the registry and
    must match the target's vocab."""
    if name != "tiny":
        from repro.configs.registry import get_config

        draft = get_config(name)
        if draft.vocab_size != cfg.vocab_size:
            raise ValueError(
                f"draft {name}: vocab {draft.vocab_size} != target "
                f"vocab {cfg.vocab_size} — draft ids must be target ids"
            )
        check_spec_arch(draft)
        return draft
    heads = max(1, min(cfg.num_heads, 2))
    d_model = max(2 * heads, min(cfg.d_model, 128))
    return dataclasses.replace(
        cfg,
        name=f"{cfg.name}-draft-tiny",
        num_layers=2,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=1,
        head_dim=0,  # -> d_model // heads
        d_ff=min(cfg.d_ff, 4 * d_model) if cfg.d_ff else 0,
        block_pattern=(),
        num_precision_groups=1,
        remat=False,
    )


@dataclasses.dataclass
class DraftBundle:
    """A ready-to-serve draft model: config + sharded weight storage.
    Build one with :func:`build_draft`, or construct directly (tests
    pass the *target's* own tree to pin 100% acceptance)."""

    cfg: ModelConfig
    spec_tree: object
    storage: object


def build_draft(
    cfg: ModelConfig, mesh_cfg: MeshCfg, name: str = "tiny", *, seed: int = 1
) -> DraftBundle:
    """Initialize a draft model on the same mesh as the target."""
    dcfg = make_draft_config(cfg, name)
    params, metas = init_params(dcfg, jax.random.PRNGKey(seed), tp=mesh_cfg.tp)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    return DraftBundle(dcfg, spec_tree, storage)


def rollback_caches(caches, delta):
    """Re-stamp every cache node's per-slot ``pos`` back by ``delta``
    (the per-slot count of rejected verify positions). Data stays put:
    positions past the new ``pos`` are mask-invisible and the next
    block overwrites them. Engine caches are ``[group][node]`` with
    ``pos (reps, slots)``; ``delta (slots,)``."""

    def one_node(n):
        if isinstance(n, M.PagedQuantKVCache):
            return M.PagedQuantKVCache(
                n.k, n.v, n.k_scale, n.v_scale, n.pos - delta[None, :]
            )
        if isinstance(n, M.PagedKVCache):
            return M.PagedKVCache(n.k, n.v, n.pos - delta[None, :])
        if isinstance(n, M.QuantKVCache):
            return M.QuantKVCache(
                n.k, n.v, n.k_scale, n.v_scale, n.pos - delta[None, :]
            )
        if isinstance(n, M.KVCache):
            return M.KVCache(n.k, n.v, n.pos - delta[None, :])
        raise TypeError(
            f"speculative rollback covers attention caches only "
            f"(got {type(n).__name__})"
        )

    return [
        {key: one_node(n) for key, n in group.items()} for group in caches
    ]


class DraftRunner:
    """The engine-side draft loop: per-slot contiguous caches kept in
    lockstep with the target's emitted streams (same ``pos`` invariant,
    same rollback deltas), one compiled ``(B, 1)`` decode program, one
    prefill program per prompt length.

    Per round, :meth:`propose` runs ``k+1`` micro decode steps: step j
    feeds the previous token (the slot's last emitted id for j=0) and
    samples proposal ``d_{j+1}`` with the request key at emitted index
    ``n + j`` — the same key the target will use for that position, so
    a draft that equals the target proposes exactly the target's
    stream (100% acceptance). The final micro step only absorbs the
    last proposal into the cache (its logits belong to the *next*
    round); without it the draft would be one position short whenever
    a full block is accepted.
    """

    def __init__(
        self,
        bundle: DraftBundle,
        mesh_cfg: MeshCfg,
        mesh,
        *,
        plan: PrecisionPlan,
        max_slots: int,
        capacity: int,
        spec_k: int,
        token_width: int,
    ):
        check_spec_arch(bundle.cfg)
        cfg = bundle.cfg
        self.cfg = cfg
        self.mesh_cfg = mesh_cfg
        self.mesh = mesh
        self.spec_tree = bundle.spec_tree
        self.storage = bundle.storage
        self.max_slots = int(max_slots)
        self.capacity = int(capacity)
        self.spec_k = int(spec_k)
        self.token_width = int(token_width)
        # the draft reuses the serving plan under its own group count;
        # the first weight entry governs all draft groups (drafts are
        # accuracy-irrelevant: they only move the acceptance rate)
        self.plan = dataclasses.replace(
            plan,
            weights=(plan.weights[0],) * (cfg.num_groups + 1),
            seq_parallel=False,
        )
        B = self.max_slots
        self._decode = make_decode_step(
            cfg, mesh_cfg, mesh, self.spec_tree,
            {
                "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
            },
            plan=self.plan, shard_batch=False, slot_caches=True,
        )
        self._prefill_cache: dict[int, object] = {}
        self._unpack = jax.jit(unpack_tokens)
        vocab = cfg.vocab_size
        width = self.token_width

        def sample_rng_pack(logits, temp, top_p, top_k, seed, step):
            tok = sample_tokens(
                logits[:, -1], vocab, temp, top_p, top_k, seed, step
            )
            return tok, pack_tokens(tok, width)

        self._sample_rng = jax.jit(sample_rng_pack)

        def insert(big, small, slot):
            def one(b, s):
                if b.ndim == s.ndim:
                    return b.at[:, slot].set(s[:, 0])
                return b.at[:, slot].set(s)

            return jax.tree_util.tree_map(one, big, small)

        self._insert = jax.jit(insert, donate_argnums=(0,))
        self._rollback = jax.jit(rollback_caches, donate_argnums=(0,))
        self.caches = None

    def _prefill(self, prompt_len: int):
        if prompt_len not in self._prefill_cache:
            self._prefill_cache[prompt_len] = make_prefill_step(
                self.cfg, self.mesh_cfg, self.mesh, self.spec_tree,
                {"tokens": jax.ShapeDtypeStruct((1, prompt_len), jnp.int32)},
                plan=self.plan, cache_capacity=self.capacity,
                shard_batch=False,
            )
        return self._prefill_cache[prompt_len]

    def reset(self) -> None:
        shapes = global_cache_shapes(
            self.cfg, self.mesh_cfg, self.max_slots, self.capacity,
            self.plan.compute_dtype, shard_batch=False, per_slot=True,
            int8_kv=self.plan.int8_kv,
        )
        self.caches = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), shapes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    def prefill_insert(self, tokens_dev, slot: int) -> None:
        """Absorb an admitted prompt into the draft's slot caches. The
        prompt ids are already device-resident (the engine's priced
        admission staging) — no second h2d crossing; migrated
        admissions re-stage and price the prompt themselves."""
        _, pcaches = self._prefill(tokens_dev.shape[1])(
            self.storage, {"tokens": tokens_dev}
        )
        self.caches = self._insert(self.caches, pcaches, np.int32(slot))

    def propose(self, next_tok, pos_host, nemit, temp, top_p, top_k,
                seed, rec) -> np.ndarray:
        """One draft round: propose ``(B, spec_k)`` ids, advancing the
        draft caches by ``spec_k + 1`` positions (rolled back by the
        engine after acceptance). Every feed/proposal crossing is
        priced into ``rec["host_device"]`` as plane bytes."""
        B, k, w = self.max_slots, self.spec_k, self.token_width
        feed = np.asarray(next_tok, np.int32).copy()
        drafts = np.zeros((B, k), np.int32)
        for j in range(k + 1):
            planes = pack_tokens_host(feed[:, None], w)  # (w, B, 1)
            rec["host_device"] += planes.nbytes
            batch = {
                "tokens": self._unpack(stage(planes)),
                "pos": stage(pos_host + j),
            }
            logits, self.caches = self._decode(
                self.storage, self.caches, batch
            )
            if j == k:
                break  # absorb the last proposal only; its logits
                # belong to the next round
            _, out_planes = self._sample_rng(
                logits, temp, top_p, top_k, seed, nemit + j
            )
            out_planes = np.asarray(out_planes)  # (w, B) — d2h proposal
            rec["host_device"] += out_planes.nbytes
            feed = unpack_tokens_host(out_planes).astype(np.int32)
            drafts[:, j] = feed
        return drafts

    def rollback(self, delta) -> None:
        self.caches = self._rollback(self.caches, delta)
