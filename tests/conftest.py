"""Test bootstrap.

Provides a minimal in-repo fallback for `hypothesis` when the real
package is unavailable (offline containers): the property tests then run
against a deterministic seeded sampler instead of failing collection.
Real environments get the genuine article via ``pip install -e .[dev]``
(declared in pyproject.toml).
"""
import importlib.util
import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on environment
    _path = os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
